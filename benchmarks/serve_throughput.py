"""Serving throughput of the continuous-batching engine
(scheduler / kv-manager / runner split, chunked bucketed prefill),
measured for BOTH execution backends side by side:

- ``reference``  — quantize-then-matmul XLA execution;
- ``quantized``  — the W(1+1)A(1x4) Pallas kernels own the hot path
  (popcount GEMV decode, dequant-in-VMEM GEMM prefill chunks, INT4
  flash-decode attention).

Measures end-to-end tokens/sec, TTFT/ITL, the prefill/decode time
split, and jitted-dispatch/compile counts for the shared-INT4-KV-cache
engine at 1/4/8 slots on a small dense LM.  Headline invariants:

- ONE ``decode_step`` dispatch per generation step at any slot count
  (``dispatches/step``), on either backend;
- prefill compilations bounded by the chunk-bucket count — prompts of
  ANY length stream through fixed-size padded chunks, so there is no
  per-prompt-length recompile storm;
- decode dispatches keep landing while a long prompt is being
  chunk-prefilled (``interleaved`` > 0 under mixed traffic);
- greedy token streams are IDENTICAL across backends AND across KV
  layouts (dense slot rows vs the paged block pool) at f32 compute;
- on the paged layout, identical prompt prefixes occupy ONE set of
  pool blocks (``blocks_saved_by_sharing`` > 0) and every block is
  returned when its streams finish.

KV memory stats (pool MiB, blocks in use / peak / total, blocks saved
by prefix sharing) are reported next to tok/s and persisted into both
``experiments/serve/throughput.json`` and the ``BENCH_serve.json``
baseline.

    PYTHONPATH=src python -m benchmarks.serve_throughput [--quick|--tiny]

``--tiny`` is the CI serve-smoke lane: a seconds-scale run that ASSERTS
the invariants above for both backends, then gates
``decode_tokens_per_sec`` against the committed ``BENCH_serve.json``
baseline (>20% regression fails; the delta is always printed).  After a
legitimate perf change, refresh the baseline with
``--tiny --update-baseline`` and commit the file (see docs/ci.md).

Also writes the full records to ``experiments/serve/throughput.json``
(the BENCH json sidecar next to the CSV rows ``run.py`` collects;
uploaded as a build artifact by the serve-smoke CI lane).

Extra modes:

- ``--sweep`` grids the paged-layout tuning knobs (``block_size`` =
  ``kv_chunk``, the bit-parity coupling) over the tiny config and
  prints decode tok/s + the paged/dense ratio per cell — how the
  shipped ``--block-size`` default was chosen.
- ``--policy speculative`` (with ``--tiny``) adds a speculative-decoding
  cell: every stream drafts ``--spec-k`` tokens with the ``--draft``
  substrate and verifies them in one batched dispatch — greedy streams
  must stay bit-identical to plain decode, and ``accept_rate`` /
  ``effective_tokens_per_sec`` land in the artifact (never speed-gated).
- ``--tp N`` records tensor-parallel cells (quantized backend, dense +
  paged, mesh sizes {1, N}) into
  ``experiments/serve/throughput_tp.json`` and asserts greedy-stream
  parity across mesh sizes.  TP cells are NEVER speed-gated: on CI
  they run on forced host devices (CPU slices), where absolute tok/s
  is meaningless.
- ``--decode-horizon k`` measures every cell with ``k`` decode
  iterations folded into one jitted dispatch (multi-step decode); in
  ``--tiny`` mode it also adds a cell asserting bit-identical streams
  vs horizon 1 plus the ``decode_dispatches == ceil(tokens/k)``
  contract.
- ``--profile`` (with ``--tiny``) wraps the gated decode measurement in
  ``jax.profiler.trace`` and records the trace dir in the artifact, so
  latency work starts from a profile instead of guesses.
"""
from __future__ import annotations

import contextlib
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import bench_arch, default_qcfg
from repro.core.quantize_model import quantize_model_sequential
from repro.models.model import build_model
from repro.serve.engine import (EngineConfig, GreedyPolicy, Request,
                                SamplingParams, ServeEngine,
                                SpeculativePolicy)

_ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT_PATH = os.path.join(_ROOT, "experiments", "serve", "throughput.json")
OUT_TP_PATH = os.path.join(_ROOT, "experiments", "serve",
                           "throughput_tp.json")
BASELINE_PATH = os.path.join(_ROOT, "BENCH_serve.json")
# shipped paged-layout default, chosen by ``--sweep`` (larger pages =
# larger flash-decode KV chunks = fewer kernel dispatches per step).
# Measured sweep on the tiny config: paged decode climbs 1512 -> 1937
# -> 2496 tok/s over block 8 -> 16 -> 32 (paged/dense 0.51 -> 0.57 ->
# 0.80) then plateaus at 64 (2438 tok/s, 0.81); 128 only "wins" (0.90)
# because it degenerates to one block per full 128-token sequence.  32
# keeps 4 blocks per sequence while recovering ~99% of the plateau.
# CI's serve-smoke lane pins ``--block-size 16`` explicitly to keep
# forcing multi-block traffic.
DEFAULT_BLOCK_SIZE = 32
BASELINE_TOLERANCE = 0.20       # fail the gate below (1 - tol) * baseline
# the machine-independent quantized/reference ratio gets a TIGHTER gate
# than the absolute tok/s cells (same-machine noise mostly cancels;
# cross-run drift does not hit both cells perfectly evenly, hence not
# 0), and it is RATCHETED: --update-baseline refuses to write a lower
# ratio than the committed one (docs/ci.md "Perf-regression gate")
RATIO_TOLERANCE = 0.10
# the ratchet's destination: the paper's claim is that the quantized
# path is CHEAPER, i.e. quantized/reference >= 1.0.  Every gated run
# records progress toward this milestone in the artifact (the committed
# baseline ratio is the floor, this is the ceiling being climbed)
RATIO_TARGET = 1.0


def _requests(n, vocab, max_new, seed=0, long_every=0, long_len=100,
              shared_prefix=0):
    """Mixed-length traffic; every ``long_every``-th request gets a long
    prompt so admission overlaps live decode streams.  With
    ``shared_prefix`` > 0, every SECOND request starts with the same
    ``shared_prefix``-token system prompt — the paged engine stores
    those prefix blocks once (dense engines just see longer prompts)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, shared_prefix).astype(np.int32)
    reqs = []
    for i in range(n):
        is_long = bool(long_every) and i % long_every == long_every - 1
        p = rng.integers(0, vocab,
                         long_len if is_long else 6 + (i % 5)).astype(np.int32)
        if shared_prefix and not is_long and i % 2 == 0:
            p = np.concatenate([prefix, p])
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=max_new))
    return reqs


def _measure(model, params, vocab, *, slots, n_requests, max_new, max_len,
             backend="reference", kv_layout="dense", block_size=32,
             shared_prefix=0, kernel_interpret=None, decode_horizon=1):
    engine = ServeEngine(model, params, config=EngineConfig(
        batch_slots=slots, max_len=max_len, backend=backend,
        kv_layout=kv_layout, block_size=block_size,
        kernel_interpret=kernel_interpret, decode_horizon=decode_horizon))
    # warmup compiles outside the timed window: decode (1), one prefill
    # per chunk bucket (bounded — NOT one per distinct prompt length)
    engine.generate(_requests(max(slots, 5), vocab, 2, seed=123,
                              long_every=3, long_len=max_len - 28))
    engine.generate(_requests(n_requests, vocab, max_new, seed=0,
                              long_every=4, long_len=max_len - 28,
                              shared_prefix=shared_prefix))
    return dict(engine.last_stats)


def _kv_summary(st):
    """Compact KV memory line from a stats dict: pool MiB + (paged)
    block occupancy and sharing wins."""
    kv = st.get("kv", {})
    mib = kv.get("pool_bytes", 0) / 2**20
    if kv.get("layout") != "paged":
        return f"{mib:.2f}MiB dense"
    return (f"{mib:.2f}MiB {kv['blocks_peak_in_use']}/{kv['blocks_total']}"
            f"blk@{kv['block_size']} shared-{kv['blocks_saved_by_sharing']}")


def _fmt_row(label, slots, st):
    return (f"  {label:<15}  {slots:<5}  {st['tokens_per_sec']:<7.1f}"
            f"  {st['ttft_ms'] or 0:<8.0f}  {st['itl_ms'] or 0:<7.0f}"
            f"  {st['itl_p95_ms'] or 0:<7.0f}  {st['decode_steps']:<5}  "
            f"{st['dispatches_per_step']:<9.0f}  "
            f"{st['tokens_per_dispatch']:<8.2f}  "
            f"{st['prefill_compiles']}/{len(st['chunk_buckets'])}"
            f"{'':<13}  {st['interleaved_steps']:<11}  {_kv_summary(st)}"
            f"  q{st['queue_ms'] or 0:.0f}ms"
            f" w{st['block_waits']} p{st['preemptions']}")


def run(quick: bool = False, block_size: int = 16, kernel_interpret=None,
        decode_horizon: int = 1):
    # kv_chunk=block_size keeps the flash-decode kernel's chunk split
    # identical across layouts, so dense and paged streams stay
    # bit-identical (docs/serving.md "Paged KV cache")
    cfg = bench_arch(d_model=128, n_layers=2).replace(max_seq_len=128)
    model = build_model(cfg, kv_chunk=block_size)
    params = model.init(jax.random.PRNGKey(0))
    calib = jax.numpy.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (4, 256)))
    qparams = quantize_model_sequential(model, params, calib,
                                        default_qcfg(em_iters=4))

    slot_counts = (1, 4) if quick else (1, 4, 8)
    n_requests = 8
    max_new = 8 if quick else 16

    rows, records = [], []
    print("  variant          slots  tok/s    ttft_ms   itl_ms   itl_p95"
          "  steps  disp/step  tok/disp  prefill_compiles  interleaved  kv")
    # both execution backends over the same quantized weights (dense and
    # paged KV layouts), plus the fp-params reference as the unquantized
    # anchor
    for label, p, backend, layout in (
            ("fp", params, "reference", "dense"),
            ("quant-ref", qparams, "reference", "dense"),
            ("quant-ref-paged", qparams, "reference", "paged"),
            ("quant-kern", qparams, "quantized", "dense"),
            ("quant-kern-paged", qparams, "quantized", "paged")):
        for slots in slot_counts:
            # identical traffic for every variant (dense engines just
            # prefill the shared prefix) so rows are comparable
            st = _measure(model, p, cfg.vocab_size, slots=slots,
                          n_requests=n_requests, max_new=max_new,
                          max_len=128, backend=backend, kv_layout=layout,
                          block_size=block_size, shared_prefix=40,
                          kernel_interpret=kernel_interpret,
                          decode_horizon=decode_horizon)
            rec = {"variant": label, "backend": backend,
                   "kv_layout": layout, **st,
                   "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}
            records.append(rec)
            print(_fmt_row(label, slots, st))
            rows.append({
                "name": f"serve/{label}_slots{slots}",
                "us_per_call": 1e6 / max(st["tokens_per_sec"], 1e-9),
                "derived": (f"{st['tokens_per_sec']:.1f}tok_per_s_"
                            f"{st['dispatches_per_step']:.0f}disp_per_step_"
                            f"{st['ttft_ms'] or 0:.0f}ms_ttft"),
            })

    _write(records)
    return rows


def _tiny_quantized_setup(block_size: int):
    """Shared tiny model + quantized params for the smoke/sweep/tp
    modes (kv_chunk = block_size: the cross-layout bit-parity
    coupling)."""
    cfg = bench_arch(d_model=64, n_layers=2).replace(max_seq_len=128,
                                                     dtype="float32")
    model = build_model(cfg, kv_chunk=block_size)
    params = model.init(jax.random.PRNGKey(0))
    calib = jax.numpy.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 256)))
    qparams = quantize_model_sequential(
        model, params, calib, default_qcfg(em_iters=2, calib_tokens=512))
    return cfg, model, qparams


def _best_decode_rate(model, qparams, vocab, *, backend, layout,
                      block_size, kernel_interpret, tp: int = 1,
                      reps: int = 3):
    """Best-of-``reps`` steady-state decode rate on a warm engine (same
    min-time convention as the smoke gate) + the final greedy streams."""
    engine = ServeEngine(model, qparams, config=EngineConfig(
        batch_slots=4, max_len=128, chunk_buckets=(8, 32), backend=backend,
        kv_layout=layout, block_size=block_size,
        kernel_interpret=kernel_interpret, tp=tp))
    engine.generate(_requests(4, vocab, 2, seed=123, long_every=3,
                              long_len=100))
    best, done = 0.0, None
    for _ in range(reps):
        done = engine.generate(_requests(8, vocab, 32, seed=0,
                                         long_every=4, long_len=100,
                                         shared_prefix=40))
        best = max(best, engine.last_stats["decode_tokens_per_sec"])
    return best, done, dict(engine.last_stats)


def sweep(block_sizes=(8, 16, 32, 64, 128), kernel_interpret=None):
    """Grid the paged tuning knob: ``block_size`` (= ``kv_chunk``, the
    flash-decode chunk cap) over the tiny config, quantized backend,
    dense vs paged.  Prints decode tok/s per cell and the paged/dense
    ratio — the shipped ``DEFAULT_BLOCK_SIZE`` is the smallest page
    whose ratio is within a few percent of the best."""
    records = []
    print("  kv_chunk=block_size  dense tok/s  paged tok/s  paged/dense")
    for bs in block_sizes:
        cfg, model, qparams = _tiny_quantized_setup(bs)
        cells = {}
        for layout in ("dense", "paged"):
            best, _, st = _best_decode_rate(
                model, qparams, cfg.vocab_size, backend="quantized",
                layout=layout, block_size=bs,
                kernel_interpret=kernel_interpret)
            cells[layout] = best
            records.append({"variant": f"sweep/quantized-{layout}-bs{bs}",
                            "backend": "quantized", "kv_layout": layout,
                            "block_size": bs, "gate": None, **st,
                            "decode_tokens_per_sec_best": best,
                            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")})
        ratio = cells["paged"] / cells["dense"]
        print(f"  {bs:<19}  {cells['dense']:<11.1f}  {cells['paged']:<11.1f}"
              f"  {ratio:.3f}")
    _write(records)
    return records


def tp_cells(tp: int, block_size: int = DEFAULT_BLOCK_SIZE,
             kernel_interpret=None):
    """Tensor-parallel bench cells: quantized backend, dense + paged,
    mesh sizes {1, tp}.  Greedy streams must be identical across mesh
    sizes (the TP acceptance criterion); tok/s is recorded in
    ``experiments/serve/throughput_tp.json`` but NEVER speed-gated —
    on CI these run on forced host devices."""
    if jax.device_count() < tp:
        raise SystemExit(
            f"--tp {tp} needs {tp} devices, have {jax.device_count()} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={tp})")
    cfg, model, qparams = _tiny_quantized_setup(block_size)
    records, streams = [], {}
    for layout in ("dense", "paged"):
        for mesh_tp in (1, tp):
            best, done, st = _best_decode_rate(
                model, qparams, cfg.vocab_size, backend="quantized",
                layout=layout, block_size=block_size,
                kernel_interpret=kernel_interpret, tp=mesh_tp)
            streams[(layout, mesh_tp)] = done
            records.append({"variant": f"tp/quantized-{layout}-tp{mesh_tp}",
                            "backend": "quantized", "kv_layout": layout,
                            "tp": mesh_tp, "gate": None, **st,
                            "decode_tokens_per_sec_best": best,
                            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")})
            print(f"  tp-cell[quantized-{layout} tp={mesh_tp}]: "
                  f"{best:.1f} decode tok/s (not gated)")
        assert streams[(layout, tp)] == streams[(layout, 1)], \
            f"greedy streams diverged across mesh sizes ({layout})"
        print(f"  tp parity OK[{layout}]: greedy streams identical at "
              f"tp=1 and tp={tp}")
    _write(records, path=OUT_TP_PATH)
    return records


def _session_smoke(model, qparams, vocab, block_size: int) -> dict:
    """Drive the session-based request API with a mixed traffic shape —
    low-priority background streams, a preempting high-priority
    arrival, a cancellation storm (queued + live), and a fork tree —
    and assert the lifecycle invariants CI cares about: no slot or
    block leaks after the storm, preemption + queue-time observable in
    stats, forked greedy streams exact, compile contract intact."""
    rng = np.random.default_rng(7)
    prompt = lambda n: rng.integers(0, vocab, n).astype(np.int32)
    # 13 blocks of 16: four background streams need 3 each (24 prompt +
    # 24 new), so the high-priority arrival (2 blocks) must preempt
    eng = ServeEngine(model, qparams, config=EngineConfig(
        batch_slots=4, max_len=128, chunk_buckets=(8, 32),
        kv_layout="paged", block_size=block_size,
        num_blocks=-(-48 // block_size) * 4 + 1))
    bg = [eng.submit(prompt(24), SamplingParams(max_new_tokens=24),
                     priority=5) for _ in range(4)]
    while sum(len(h.out_tokens) > 0 for h in bg) < 2:
        eng.step()
    hp = eng.submit(prompt(16), SamplingParams(max_new_tokens=12),
                    priority=0)
    extras = [eng.submit(prompt(12), SamplingParams(max_new_tokens=8),
                         priority=9) for _ in range(2)]
    eng.step(), eng.step()
    for h in extras:                    # storm: cancel while queued
        h.cancel()
    victim_live = next(h for h in bg if h.status == "decode")
    victim_live.cancel()                # storm: cancel a live stream
    while hp.status != "done":
        eng.step()
    donor = next(h for h in bg if h.status == "decode")
    forks = donor.fork(1)               # copy-free beam branch
    eng.drain()
    st = eng.last_stats
    assert hp.out_tokens and len(hp.out_tokens) == 12
    assert st["preemptions"] >= 1, st
    assert st["cancelled"] == 3, st
    assert st["forks"] == 1, st
    assert st["queue_ms"] is not None
    assert all(h.status == "done" for h in bg if h is not victim_live)
    # a greedy fork with inherited params reproduces its donor exactly
    assert forks[0].out_tokens == donor.out_tokens
    # the storm + preemption left NOTHING behind
    kv = st["kv"]
    assert kv["blocks_in_use"] == 0, kv
    assert eng.kv.pool.n_free == eng.kv.pool.num_blocks
    assert eng.scheduler.kv.n_free == 4
    assert st["dispatches_per_step"] == 1.0, st
    assert st["prefill_compiles"] <= len(eng.runner.chunk_buckets), st
    print(f"  serve-smoke[session] OK: {st['tokens']} tokens, "
          f"{st['preemptions']} preemptions, {st['cancelled']} cancels, "
          f"{st['forks']} forks, queue {st['queue_ms']:.0f}ms, "
          f"{st['block_waits']} block-waits, no slot/block leaks")
    return {"variant": "tiny-smoke/session", "backend": "reference",
            "kv_layout": "paged", "gate": None, **st,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}


def _sanitize_smoke(model, qparams, vocab, block_size: int) -> dict:
    """CI sanitized-serve cell (``--sanitize``): a dedicated engine
    with ``EngineConfig(sanitize=True)`` — refcount shadow ledger,
    recompile sentry, donation guard, NaN tripwire all live.

    Each serving window runs the same mixed traffic (both prefill
    buckets, long prompts, shared prefixes) PLUS a fork whose divergent
    write exercises the copy-on-write block copy — the one jit entry
    plain drain-style traffic never touches (prefix sharing only
    registers FULL blocks, so shared-prefix streams alone never
    diverge inside a block).  The first window is the warmup: every
    entry compiles there, and closing it arms the recompile sentry.
    The armed repeats then prove the acceptance contract at runtime:
    ZERO compiles after warmup while still dispatching COW copies (a
    cache miss would hard-error in the sentry), a fully drained block
    pool at every window close (a leak would hard-error in the
    auditor), streams bit-identical to an unsanitized engine, and
    ``sanitizer_checks_passed`` > 0 in the artifact record as evidence
    the auditors actually ran."""

    def drive(eng, seed):
        """One serving window: 2 short streams + a fork of one of them
        (shares the partial tail block -> COW on the next write), then
        the full mixed-traffic request set queued behind them."""
        reqs = _requests(8, vocab, 32, seed=seed, long_every=4,
                         long_len=100, shared_prefix=40)
        lead = [eng.submit(r.prompt,
                           SamplingParams(max_new_tokens=r.max_new_tokens))
                for r in reqs[:2]]
        while not any(h.status == "decode" and h.out_tokens
                      for h in lead):
            eng.step()
        donor = next(h for h in lead
                     if h.status == "decode" and h.out_tokens)
        forked, = donor.fork(1)
        rest = [eng.submit(r.prompt,
                           SamplingParams(max_new_tokens=r.max_new_tokens))
                for r in reqs[2:]]
        eng.drain()
        # a greedy fork with inherited params reproduces its donor
        assert forked.out_tokens == donor.out_tokens
        assert eng.kv.pool.stats()["cow_copies"] > 0
        return [h.out_tokens for h in lead + rest + [forked]]

    eng = ServeEngine(model, qparams, config=EngineConfig(
        batch_slots=4, max_len=128, chunk_buckets=(8, 32),
        kv_layout="paged", block_size=block_size, sanitize=True))
    plain = ServeEngine(model, qparams, config=EngineConfig(
        batch_slots=4, max_len=128, chunk_buckets=(8, 32),
        kv_layout="paged", block_size=block_size))
    # warmup window: compiles everything, then arms the sentry at close
    warm = drive(eng, seed=123)
    assert eng.sanitizer.armed, "sentry must arm at the first idle"
    warm_compiles = dict(eng.sanitizer.compiles)
    assert warm_compiles.get("copy_block"), \
        f"warmup never compiled the COW copy — sentry trap: {warm_compiles}"
    assert drive(plain, seed=123) == warm, \
        "sanitize=True perturbed greedy streams"
    for seed in (0, 1):     # armed repeats: any cache miss raises
        done = drive(eng, seed)
        assert drive(plain, seed) == done, \
            "sanitize=True perturbed greedy streams"
        assert eng.sanitizer.compiles == warm_compiles, \
            (eng.sanitizer.compiles, warm_compiles)
        assert eng.kv_stats["blocks_in_use"] == 0
        assert eng.kv.pool.n_free == eng.kv.pool.num_blocks
    st = eng.last_stats
    assert st["sanitizer_checks_passed"] > 0, st
    print(f"  serve-smoke[sanitized] OK: {eng.sanitizer.windows_closed} "
          f"windows, {st['sanitizer_checks_passed']} checks passed, "
          f"0 recompiles after warmup "
          f"({sum(warm_compiles.values())} warmup compiles over "
          f"{len(warm_compiles)} jit entries), pool drained at every "
          f"close, streams bit-identical to the unsanitized engine")
    return {"variant": "tiny-smoke/sanitized", "backend": "reference",
            "kv_layout": "paged", "gate": None, **st,
            "warmup_compiles": warm_compiles,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}


def sanitize_smoke(block_size: int = 16) -> dict:
    """Standalone sanitized-serve run (``--sanitize`` without
    ``--tiny``): the CI static-analysis lane's runtime half — just the
    quantized tiny setup + the sanitized cell, no perf gating, record
    written to its own artifact."""
    cfg, model, qparams = _tiny_quantized_setup(block_size)
    rec = _sanitize_smoke(model, qparams, cfg.vocab_size, block_size)
    _write([rec], path=os.path.join(_ROOT, "experiments", "serve",
                                    "sanitize.json"),
           extra={"block_size": block_size})
    return rec


def _policy_smoke(model, qparams, vocab, block_size: int,
                  draft: str = "tiny", k: int = 3) -> dict:
    """CI speculative-decoding cell: every stream decoded via
    draft-and-verify (``SpeculativePolicy``) on the quantized backend's
    paged engine.  Greedy speculative streams must be BIT-IDENTICAL to
    the plain decode path (the verify logits are authoritative), the
    verify step must hold its compile contract (ONE shape under a
    uniform k), and the draft must produce accepted tokens.  The
    record's ``effective_tokens_per_sec`` / ``accept_rate`` ride in the
    artifact but are never speed-gated (draft quality on random tiny
    weights is not the shipped operating point)."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, vocab, 6 + (i % 5)).astype(np.int32)
               for i in range(8)]

    def drive(pol):
        eng = ServeEngine(model, qparams, config=EngineConfig(
            batch_slots=4, max_len=128, chunk_buckets=(8, 32),
            backend="quantized", kv_layout="paged",
            block_size=block_size))
        outs = [h.result() for h in
                [eng.submit(p, SamplingParams(max_new_tokens=24,
                                              policy=pol))
                 for p in prompts]]
        return eng, outs

    _, ref = drive(GreedyPolicy())
    eng, got = drive(SpeculativePolicy(k=k, draft=draft))
    st = eng.stats()
    assert got == ref, \
        "speculative greedy streams diverged from plain decode"
    assert st.accept_rate is not None and st.accept_rate > 0, st
    assert st.drafted_tokens > 0 and st.accepted_tokens >= 0, st
    assert st.verify_dispatches > 0, st
    assert eng.runner.verify_compiles == 1, eng.runner.verify_compiles
    assert st.effective_tokens_per_sec is not None \
        and st.effective_tokens_per_sec > 0, st
    if draft == "self":       # self-draft on greedy streams ~always wins
        assert st.accepted_tokens_per_step > 1, st
    assert eng.kv_stats_typed.blocks_in_use == 0, eng.kv_stats_typed
    print(f"  serve-smoke[speculative-{draft}] OK: k={k}, "
          f"accept_rate={st.accept_rate:.2f}, "
          f"{st.accepted_tokens_per_step:.2f} accepted tok/verify-step, "
          f"{st.verify_dispatches} verify dispatches "
          f"({eng.runner.verify_compiles} compile), "
          f"{st.effective_tokens_per_sec:.1f} effective tok/s, greedy "
          "streams bit-identical to plain decode")
    return {"variant": f"tiny-smoke/speculative-{draft}",
            "backend": "quantized", "kv_layout": "paged",
            "policy": "speculative", "draft": draft, "spec_k": k,
            "gate": None, **st.as_dict(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}


def _horizon_smoke(model, qparams, vocab, block_size: int, k: int,
                   streams_at_k: dict) -> dict:
    """CI multi-step decode cell (``--decode-horizon k``): the
    quantized-paged engine re-run at decode_horizon=1 must produce
    BIT-IDENTICAL greedy streams to the horizon-``k`` gate cells
    (``streams_at_k``), and a lone drained stream must obey the
    dispatch-count contract ``decode_dispatches == ceil(tokens / k)``
    (its first token comes from prefill, the rest from ceil((n-1)/k)
    scanned dispatches)."""
    def drive(horizon, reqs):
        eng = ServeEngine(model, qparams, config=EngineConfig(
            batch_slots=4, max_len=128, chunk_buckets=(8, 32),
            backend="quantized", kv_layout="paged",
            block_size=block_size, decode_horizon=horizon))
        return eng, eng.generate(reqs)

    _, done1 = drive(1, _requests(8, vocab, 32, seed=0, long_every=4,
                                  long_len=100, shared_prefix=40))
    assert done1 == streams_at_k, \
        f"greedy streams diverged between decode_horizon 1 and {k}"
    rng = np.random.default_rng(3)
    eng, done = drive(k, [Request(
        rid=0, prompt=rng.integers(0, vocab, 9).astype(np.int32),
        max_new_tokens=33)])
    st = eng.stats()
    want = -(-(33 - 1) // k)
    assert st.decode_dispatches == want, \
        (f"dispatch-count contract: {st.decode_dispatches} dispatches "
         f"for 32 decode tokens at horizon {k}, want {want}")
    assert st.tokens_per_dispatch > 1.0, st
    print(f"  serve-smoke[horizon-{k}] OK: streams bit-identical to "
          f"horizon 1; lone stream drained 32 decode tokens in "
          f"{st.decode_dispatches} dispatches (= ceil(32/{k}); "
          f"{st.tokens_per_dispatch:.2f} tok/dispatch)")
    return {"variant": f"tiny-smoke/horizon-{k}", "backend": "quantized",
            "kv_layout": "paged", "decode_horizon": k, "gate": None,
            **st.as_dict(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}


def tiny_smoke(baseline_path: str = BASELINE_PATH,
               update_baseline: bool = False, block_size: int = 16,
               kernel_interpret=None, policy: str = "greedy",
               draft: str = "tiny", spec_k: int = 3,
               decode_horizon: int = 1, profile: bool = False,
               sanitize: bool = False) -> dict:
    """CI serve-smoke lane: seconds-scale run of BOTH backends x BOTH
    KV layouts over the same quantized weights, asserting the serving
    invariants (module docstring), greedy-stream parity across every
    (backend, layout) cell, paged-pool hygiene (multi-block sequences
    via a small ``block_size``, prefix blocks stored once, no leaked
    blocks), and the ``BENCH_serve.json`` perf gate.

    ``decode_horizon`` > 1 measures every gate cell with k decode
    iterations per jitted dispatch AND adds a dedicated horizon cell
    asserting bit-identical streams vs horizon 1 plus the
    dispatch-count contract (docs/serving.md "Multi-step decode").
    ``profile=True`` wraps the gated measurement in
    ``jax.profiler.trace`` and records the trace dir in the artifact.
    """
    cfg, model, qparams = _tiny_quantized_setup(block_size)

    trace_dir = None
    if profile:
        trace_dir = os.path.join(_ROOT, "experiments", "serve", "trace",
                                 time.strftime("%Y%m%dT%H%M%S"))
        os.makedirs(trace_dir, exist_ok=True)

    records, streams, dense_engines = [], {}, {}
    traffic = dict(long_every=4, long_len=100, shared_prefix=40)
    for backend in ("reference", "quantized"):
        for layout in ("dense", "paged"):
            gate = backend if layout == "dense" else f"{backend}-paged"
            engine = ServeEngine(model, qparams, config=EngineConfig(
                batch_slots=4, max_len=128, chunk_buckets=(8, 32),
                backend=backend, kv_layout=layout, block_size=block_size,
                kernel_interpret=kernel_interpret,
                decode_horizon=decode_horizon))
            # warmup so decode_tokens_per_sec measures steady state, not jit
            engine.generate(_requests(4, cfg.vocab_size, 2, seed=123,
                                      long_every=3, long_len=100))
            # 8 requests x 32 new tokens per repeat; the serve itself is
            # ~0.1 s (the run cost is all jit compiles), so one timing is
            # scheduler-noise — repeat on the warm engine and gate the
            # BEST decode rate (min-time convention: interference only
            # ever slows a run down; ~1 s extra, greedy repeats identical)
            t0 = time.perf_counter()
            reps = []
            tracer = (jax.profiler.trace(trace_dir) if trace_dir
                      else contextlib.nullcontext())
            with tracer:
                for _ in range(5):
                    done = engine.generate(_requests(8, cfg.vocab_size, 32,
                                                     seed=0, **traffic))
                    # typed snapshot (ServeStats) — the gate path reads
                    # attributes, the artifact keeps the as_dict() schema
                    reps.append((engine.stats(), done))
            dt = time.perf_counter() - t0
            assert all(r[1] == done for r in reps), \
                "greedy streams diverged across repeats"
            best = max(reps, key=lambda r: r[0].decode_tokens_per_sec)[0]
            st = best.as_dict()
            assert len(done) == 8 and all(len(v) > 0 for v in done.values())
            assert best.dispatches_per_step == 1.0, best
            assert best.prefill_compiles <= \
                len(engine.runner.chunk_buckets), best
            assert best.interleaved_steps > 0, best  # decode kept flowing
            if layout == "paged":
                # multi-block sequences actually exercised + pool hygiene
                kvt = best.kv
                assert kvt.blocks_peak_in_use > engine.slots, kvt
                assert kvt.blocks_saved_by_sharing > 0, kvt
                assert kvt.blocks_in_use == 0, kvt      # all freed
                assert best.shared_prefix_tokens > 0, best
            if backend == "quantized":
                # the fused-projection contract: decode serves MORE
                # source linears than it pays kernel dispatches for
                # (QKV and gate/up slot-batched into single GEMVs), and
                # activation quantization never runs as its own
                # dispatch (it is fused into the GEMV grid)
                tc = engine.runner.trace_counts.get("decode", {})
                assert tc.get("decode_act_quant", 0) == 0, tc
                assert 0 < tc["decode_gemv"] < tc["decode_linears"], tc
                pst = engine.packed_stats_typed
                assert pst.fused_projections > 0, pst
                print(f"  serve-smoke[{gate}] decode trace: "
                      f"{tc['decode_gemv']} fused GEMV dispatches serve "
                      f"{tc['decode_linears']} linears "
                      f"({pst.fused_projections} slot-batched projections)")
            streams[(backend, layout)] = done
            if layout == "dense":
                dense_engines[backend] = engine
            records.append({"variant": f"tiny-smoke/{gate}",
                            "backend": backend, "kv_layout": layout,
                            "gate": gate, **st,
                            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")})
            extra = ""
            if engine.packed_stats is not None:
                ps = engine.packed_stats
                mode = "interpret" if ps["kernel_interpret"] else "compiled"
                extra = (f", {ps['packed_linears']} packed linears "
                         f"({ps['packed_bytes'] / 2**10:.0f} KiB), "
                         f"kernels {mode} on {ps['kernel_backend']}")
            print(f"  serve-smoke[{gate}] OK: {st['tokens']} tokens in "
                  f"{dt:.1f}s, {st['decode_tokens_per_sec']:.1f} decode "
                  f"tok/s, {st['dispatches_per_step']:.0f} dispatch/step, "
                  f"{st['decode_dispatches']} decode dispatches "
                  f"({st['tokens_per_dispatch']:.2f} tok/dispatch at "
                  f"horizon {decode_horizon}), itl p50/p95/p99 "
                  f"{st['itl_p50_ms'] or 0:.1f}/{st['itl_p95_ms'] or 0:.1f}"
                  f"/{st['itl_p99_ms'] or 0:.1f}ms, "
                  f"{st['prefill_compiles']} prefill compiles "
                  f"(<= {len(engine.runner.chunk_buckets)} buckets), "
                  f"{st['interleaved_steps']} interleaved steps, "
                  f"kv {_kv_summary(st)}{extra}")
    first = next(iter(streams.values()))
    assert all(v == first for v in streams.values()), \
        "greedy streams diverged across (backend, kv_layout) cells"
    print("  serve-smoke parity OK: greedy streams identical across "
          f"{len(streams)} (backend, kv_layout) cells")
    if decode_horizon > 1:
        # horizon cell: parity vs horizon 1 + the dispatch-count
        # contract (not perf-gated; rides in the artifact)
        records.append(_horizon_smoke(model, qparams, cfg.vocab_size,
                                      block_size, decode_horizon,
                                      streams[("quantized", "paged")]))
    # session-API lifecycle smoke: submit/cancel/fork/preempt traffic
    # (not perf-gated; the record rides along in the artifact)
    records.append(_session_smoke(model, qparams, cfg.vocab_size,
                                  block_size))
    if sanitize:
        # sanitized-serve cell (--sanitize): its own engine, NOT the
        # gate cells — their warmup traffic has no shared prefixes, so
        # the COW block copy would first compile mid-measurement and
        # falsely trip the armed recompile sentry
        records.append(_sanitize_smoke(model, qparams, cfg.vocab_size,
                                       block_size))
    if policy == "speculative":
        # speculative decode cell (--policy speculative): parity + the
        # draft economics ride in the artifact, never speed-gated
        records.append(_policy_smoke(model, qparams, cfg.vocab_size,
                                     block_size, draft=draft, k=spec_k))
    by_gate = {r["gate"]: r for r in records}
    # The gated quantized/reference ratio is measured from INTERLEAVED
    # serves on the two warm dense engines, not from the quotient of
    # the absolute cells: those cells run ~30 s apart, so a
    # time-varying contention burst on the runner slows ONE of them and
    # does not divide out — even best-of-5 cells left the quotient
    # swinging far outside the 10% ratio band.  Interleaving puts both
    # backends in the same measurement window, and each side takes its
    # BEST serve (the same min-time convention the absolute cells use:
    # interference only ever slows a run down — the interpret-mode
    # quantized serve is the Python-heaviest and the most often hit),
    # so the quotient of bests converges on the true machine-
    # independent ratio instead of whichever burst landed mid-pair.
    pair_reqs = _requests(8, cfg.vocab_size, 32, seed=0, **traffic)
    rates = {"reference": [], "quantized": []}
    for _ in range(5):
        for b in ("reference", "quantized"):
            dense_engines[b].generate(pair_reqs)
            rates[b].append(dense_engines[b].stats().decode_tokens_per_sec)
    ratio = max(rates["quantized"]) / max(rates["reference"])
    print(f"  backend ratio: quantized/reference = {ratio:.2f}x decode tok/s "
          f"(best-of-{len(rates['quantized'])} each over interleaved "
          "serves; machine-independent trend line; milestone target "
          f"{RATIO_TARGET:.1f} — {ratio / RATIO_TARGET:.0%} there)")
    # paged/dense decode ratio per backend: the paged-layout overhead as
    # a machine-independent number in the artifact (reported, not gated
    # — the absolute cells already gate both layouts)
    paged_ratio = {
        b: round(by_gate[f"{b}-paged"]["decode_tokens_per_sec"]
                 / by_gate[b]["decode_tokens_per_sec"], 3)
        for b in ("reference", "quantized")}
    for b, r in paged_ratio.items():
        print(f"  layout ratio[{b}]: paged/dense = {r:.3f}x decode tok/s "
              f"(block_size {block_size})")
    extra = {"paged_to_dense_ratio": paged_ratio,
             "block_size": block_size,
             "decode_horizon": decode_horizon,
             # milestone progress: every run records how far the
             # machine-independent ratio has climbed toward the paper's
             # "quantized is cheaper" target (docs/ci.md)
             "quantized_to_reference_ratio": round(ratio, 3),
             "ratio_target": RATIO_TARGET,
             "ratio_progress": round(ratio / RATIO_TARGET, 3)}
    if trace_dir is not None:
        extra["profile_trace_dir"] = os.path.relpath(trace_dir, _ROOT)
        print(f"  profiler trace written to {extra['profile_trace_dir']} "
              "(TensorBoard: tensorboard --logdir <dir>)")
    _write(records, extra=extra)
    _gate_baseline(records, baseline_path, update=update_baseline,
                   paged_ratio=paged_ratio, decode_horizon=decode_horizon,
                   ratio=ratio)
    return records[-1]


def _gate_baseline(records, path: str, *, update: bool = False,
                   paged_ratio: dict | None = None,
                   decode_horizon: int = 1, ratio: float | None = None):
    """Compare per-backend ``decode_tokens_per_sec`` against the
    committed baseline; >tolerance regression fails, delta always
    printed.  ``update=True`` rewrites the baseline instead (commit the
    result after a legitimate perf change — docs/ci.md).

    The quantized/reference ratio is a ratcheted MILESTONE check: the
    committed baseline is the floor (regressing more than
    ``ratio_tolerance`` below it fails), ``RATIO_TARGET`` is the
    destination, and every run prints + records how far along the climb
    the tree currently is."""
    measured = {r["gate"]: float(r["decode_tokens_per_sec"])
                for r in records if r.get("gate")}
    if ratio is None:       # paired measurement preferred (tiny_smoke)
        ratio = measured["quantized"] / measured["reference"]
    if update:
        # RATCHET: the machine-independent ratio may only climb.  A
        # baseline refresh that would LOWER it is refused — a real
        # kernel-path regression must be fixed (or the old baseline
        # consciously deleted), never silently re-baselined away.
        if os.path.exists(path):
            prev = json.load(open(path)).get("quantized_to_reference_ratio")
            if prev and round(ratio, 3) < prev:
                raise SystemExit(
                    f"baseline ratchet: measured quantized/reference ratio "
                    f"{ratio:.3f} < committed {prev:.3f} — refusing to "
                    f"lower the bar; fix the kernel-path regression (or "
                    f"delete {os.path.relpath(path)} to consciously reset)")
        # KV memory snapshot rides in the baseline so the paged win
        # (pool MiB, sharing) is a committed, reviewable number too
        kv_stats = {r["gate"]: {k: r["kv"][k] for k in
                                ("pool_bytes", "blocks_total",
                                 "blocks_peak_in_use",
                                 "blocks_saved_by_sharing")
                                if k in r["kv"]}
                    for r in records
                    if r.get("gate") and r.get("kv_layout") == "paged"}
        json.dump({
            "bench": "serve_throughput --tiny",
            "tolerance": BASELINE_TOLERANCE,
            "ratio_tolerance": RATIO_TOLERANCE,
            "decode_tokens_per_sec": {k: round(v, 1)
                                      for k, v in measured.items()},
            # machine-independent: survives runner-hardware changes that
            # shift both absolute numbers together
            "quantized_to_reference_ratio": round(ratio, 3),
            # the milestone the ratchet is climbing toward (paper claim:
            # the quantized path is the CHEAPEST cell, ratio >= 1.0)
            "ratio_target": RATIO_TARGET,
            "ratio_progress": round(ratio / RATIO_TARGET, 3),
            # decode iterations per jitted dispatch when measured
            "decode_horizon": decode_horizon,
            # reported (not gated): paged-layout decode overhead per
            # backend at the CI block size
            "paged_to_dense_ratio": paged_ratio or {},
            "kv": kv_stats,
            "updated": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "update_cmd": ("PYTHONPATH=src python -m "
                           "benchmarks.serve_throughput --tiny "
                           "--update-baseline"
                           + (f" --decode-horizon {decode_horizon}"
                              if decode_horizon > 1 else "")),
        }, open(path, "w"), indent=1)
        print(f"  wrote baseline {os.path.relpath(path)}: "
              + ", ".join(f"{k}={v:.1f}" for k, v in measured.items())
              + f", ratio={ratio:.3f}")
        return
    if not os.path.exists(path):
        raise SystemExit(
            f"perf gate: baseline {os.path.relpath(path)} missing — "
            "create it with --tiny --update-baseline and commit it")
    base = json.load(open(path))
    tol = float(base.get("tolerance", BASELINE_TOLERANCE))
    failures = []
    for backend, want in base["decode_tokens_per_sec"].items():
        got = measured.get(backend)
        if got is None:
            failures.append(f"{backend}: baselined but not measured")
            continue
        delta = (got - want) / want
        verdict = "OK" if got >= want * (1.0 - tol) else "REGRESSION"
        print(f"  perf gate[{backend}]: {got:.1f} vs baseline {want:.1f} "
              f"decode tok/s ({delta:+.1%}, tolerance -{tol:.0%}) {verdict}")
        if verdict != "OK":
            failures.append(
                f"{backend}: {got:.1f} < {(1 - tol) * want:.1f} "
                f"(baseline {want:.1f} - {tol:.0%})")
    want_ratio = base.get("quantized_to_reference_ratio")
    if want_ratio:
        # same-machine noise cancels in the ratio, so it gets the
        # tighter ratcheted tolerance (older baselines without the
        # field fall back to the loose absolute one)
        tolr = float(base.get("ratio_tolerance", tol))
        delta = (ratio - want_ratio) / want_ratio
        verdict = "OK" if ratio >= want_ratio * (1.0 - tolr) else "REGRESSION"
        target = float(base.get("ratio_target", RATIO_TARGET))
        print(f"  perf gate[ratio]: quantized/reference {ratio:.3f} vs "
              f"baseline {want_ratio:.3f} ({delta:+.1%}, tolerance "
              f"-{tolr:.0%}) {verdict}  [machine-independent, ratcheted "
              f"milestone: {ratio / target:.0%} of target {target:.1f}]")
        if verdict != "OK":
            failures.append(
                f"quantized/reference ratio {ratio:.3f} < "
                f"{(1 - tolr) * want_ratio:.3f}")
    if failures:
        raise SystemExit("perf gate FAILED: " + "; ".join(failures))


def _write(records, path: str = OUT_PATH, extra: dict | None = None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    json.dump({"bench": "serve_throughput", **(extra or {}),
               "records": records},
              open(path, "w"), indent=1)
    print(f"  wrote {os.path.relpath(path)}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: assert serving invariants + backend "
                         "parity + perf gate, fast")
    ap.add_argument("--baseline", default=BASELINE_PATH,
                    help="perf-gate baseline json (default BENCH_serve.json)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run instead of "
                         "gating against it (commit the result)")
    ap.add_argument("--block-size", type=int, default=DEFAULT_BLOCK_SIZE,
                    help="paged-layout block size (= flash-decode "
                         "kv_chunk); small values force multi-block "
                         "sequences (CI pins 16), the default is the "
                         "--sweep winner")
    ap.add_argument("--sweep", action="store_true",
                    help="grid block_size=kv_chunk over the tiny config "
                         "and report the paged/dense decode ratio per "
                         "cell (how the default block size was chosen)")
    ap.add_argument("--tp", type=int, default=0,
                    help="record tensor-parallel cells at this mesh size "
                         "(quantized backend, mesh {1, N}; parity "
                         "asserted, tok/s recorded but never gated)")
    ap.add_argument("--policy", default="greedy",
                    choices=("greedy", "speculative"),
                    help="--tiny only: 'speculative' adds a "
                         "draft-and-verify cell (greedy parity, "
                         "accept_rate, effective tok/s in the artifact)")
    ap.add_argument("--draft", default="tiny", choices=("self", "tiny"),
                    help="draft substrate for --policy speculative: "
                         "'self' (same weights) or 'tiny' (first scan "
                         "unit only)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per verify step for "
                         "--policy speculative")
    ap.add_argument("--kernel-interpret", default="auto",
                    choices=("auto", "on", "off"),
                    help="Pallas execution for the quantized backend: "
                         "auto = compiled on TPU/GPU, interpret on CPU "
                         "(the default); on/off force interpret mode")
    ap.add_argument("--decode-horizon", type=int, default=1,
                    help="decode iterations per jitted dispatch "
                         "(lax.scan multi-step decode); > 1 also adds "
                         "the horizon parity + dispatch-count cell in "
                         "--tiny mode (CI pins 4)")
    ap.add_argument("--profile", action="store_true",
                    help="--tiny only: wrap the gated decode "
                         "measurement in jax.profiler.trace and record "
                         "the trace dir in the artifact")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the sanitized-serve cell "
                         "(EngineConfig(sanitize=True) — recompile "
                         "sentry, refcount audits, donation guard, NaN "
                         "tripwire; asserts zero recompiles after "
                         "warmup and a drained pool, docs/analysis.md). "
                         "With --tiny it rides as an extra cell; alone "
                         "it runs standalone (the CI static-analysis "
                         "lane)")
    args = ap.parse_args()
    interp = {"auto": None, "on": True, "off": False}[args.kernel_interpret]
    if args.sweep:
        sweep(kernel_interpret=interp)
    elif args.tp:
        tp_cells(args.tp, block_size=args.block_size,
                 kernel_interpret=interp)
    elif args.tiny:
        tiny_smoke(baseline_path=args.baseline,
                   update_baseline=args.update_baseline,
                   block_size=args.block_size, kernel_interpret=interp,
                   policy=args.policy, draft=args.draft,
                   spec_k=args.spec_k,
                   decode_horizon=args.decode_horizon,
                   profile=args.profile, sanitize=args.sanitize)
    elif args.sanitize:
        # standalone sanitized cell (the CI static-analysis lane):
        # runtime auditors live, no perf gate
        sanitize_smoke(block_size=args.block_size)
    else:
        run(quick=args.quick, block_size=args.block_size,
            kernel_interpret=interp, decode_horizon=args.decode_horizon)
