"""Figure 3/4 analogue: kernel-level analysis of the binary GEMV /
dequant GEMM vs bf16 and int8 baselines.

No TPU wall clock exists in this container, so per the hardware-
adaptation note (DESIGN.md §3) we report the roofline-DERIVED speedup
bounds: bytes moved per output and the implied memory-bound time on TPU
v5e, plus measured interpret-mode correctness timing for reference.
The paper's Fig. 3 GPU result (binary beats INT4 CUTLASS ~3x) maps on
TPU to an ~8x weight-traffic reduction for decode (2 vs 16 bits) and
~(16/2.125)x for prefill streaming."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

SHAPES = [  # (T, C_in, C_out) — LLaMA-7B layer shapes from the paper
    (1, 4096, 4096),
    (1, 4096, 11008),
    (16, 4096, 4096),
    (128, 4096, 4096),
    (2048, 4096, 11008),
]


def derived_row(t, c_in, c_out):
    flops = 2.0 * t * c_in * c_out
    act = t * c_in
    out = t * c_out * 4
    w_bf16 = 2.0 * c_in * c_out
    w_int8 = 1.0 * c_in * c_out
    w_bwa = c_in * c_out * 2 / 8 + (c_in // 128) * c_out * 4 * 2  # 2b + centers
    rows = {}
    for name, wbytes, abytes in [
        ("bf16", w_bf16, act * 2),
        ("int8", w_int8, act * 1),
        ("bwa-1x4", w_bwa, act * 4 / 8 * 4),  # four 1-bit planes
    ]:
        total = wbytes + abytes + out
        t_mem = total / HBM_BW
        t_cmp = flops / PEAK_FLOPS * (1.0 if name != "int8" else 0.5)
        rows[name] = max(t_mem, t_cmp)
    return rows


def run(quick: bool = False):
    rows = []
    print("  shape (T,Cin,Cout)      bf16(us)  int8(us)  bwa(us)  "
          "speedup-vs-bf16  vs-int8")
    for t, c_in, c_out in (SHAPES if not quick else SHAPES[:2]):
        r = derived_row(t, c_in, c_out)
        sp16 = r["bf16"] / r["bwa-1x4"]
        sp8 = r["int8"] / r["bwa-1x4"]
        rows.append({
            "name": f"fig3/gemm_{t}x{c_in}x{c_out}",
            "us_per_call": r["bwa-1x4"] * 1e6,
            "derived": f"x{sp16:.2f}_vs_bf16,x{sp8:.2f}_vs_int8",
        })
        print(f"  ({t:5d},{c_in},{c_out:5d})   {r['bf16']*1e6:8.2f} "
              f"{r['int8']*1e6:9.2f} {r['bwa-1x4']*1e6:8.2f}"
              f"  {sp16:8.2f}x {sp8:9.2f}x")

    # interpret-mode wall time (correctness-path reference, not TPU perf)
    if not quick:
        from repro.kernels.bwa_matvec.kernel import bwa_matvec_kernel
        c_out, g, wg = 512, 4, 4
        r = np.random.default_rng(0)
        q = jnp.asarray(r.integers(0, 2**32, (c_out, g, wg), dtype=np.uint32))
        m = jnp.asarray(r.integers(0, 2**32, (c_out, g, wg), dtype=np.uint32))
        cd = jnp.asarray(r.normal(size=(c_out, g, 4)).astype(np.float32))
        planes = jnp.asarray(r.integers(0, 2**32, (4, 4, g, wg),
                                        dtype=np.uint32))
        pw = jnp.asarray([1.0, 2, 4, 8], jnp.float32)
        f = lambda: bwa_matvec_kernel(q, m, cd, planes, pw, block_out=128)
        f()  # compile
        t0 = time.time()
        for _ in range(5):
            f().block_until_ready()
        dt = (time.time() - t0) / 5
        rows.append({"name": "fig3/bwa_matvec_interpret",
                     "us_per_call": dt * 1e6,
                     "derived": "cpu-interpret-reference"})
        print(f"  bwa_matvec interpret-mode: {dt*1e3:.1f} ms/call "
              "(CPU correctness path)")
    return rows


if __name__ == "__main__":
    run()
