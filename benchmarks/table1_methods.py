"""Table 1/2 analogue: held-out perplexity of the tiny byte-LM under
each quantization method at matched bit budgets.

Paper claim reproduced: at the W2A4 budget every RTN-family baseline
degrades sharply while W(1+1)A(1x4) stays close to FP16; BiLLM-style
binarization collapses once activations are also quantized."""
from __future__ import annotations

import time

from benchmarks.common import (
    calib_batch,
    get_trained_lm,
    perplexity,
    quantize_baseline,
    quantize_ours,
)

METHODS = [
    ("fp16", None),
    ("rtn-w4a4", "rtn-w4a4"),
    ("atom-w4a4", "atom-w4a4"),
    ("rtn-w2a4", "rtn-w2a4"),
    ("gptq-w2a4", "gptq-w2a4"),
    ("quarot-w2a4", "quarot-w2a4"),
    ("atom-w2a4", "atom-w2a4"),
    ("billm-w(1+1)a16", "billm-a16"),
    ("billm-w(1+1)a4", "billm-a4"),
    ("ours-w(1+1)a(1x4)", "ours"),
]


def run(quick: bool = False):
    model, params, train_toks, held = get_trained_lm()
    calib = calib_batch(train_toks)
    rows = []
    methods = METHODS if not quick else METHODS[:2] + METHODS[-1:]
    for name, method in methods:
        t0 = time.time()
        if method is None:
            qp = params
        elif method == "ours":
            qp = quantize_ours(model, params, calib)
        else:
            qp = quantize_baseline(model, params, calib, method)
        ppl = perplexity(model, qp, held)
        dt = time.time() - t0
        rows.append({"name": f"table1/{name}", "us_per_call": dt * 1e6,
                     "derived": f"ppl={ppl:.3f}"})
        print(f"  {name:22s} ppl {ppl:10.3f}  ({dt:.0f}s)")
    return rows


if __name__ == "__main__":
    run()
