"""Shared benchmark substrate: a small byte-level LM trained on the
in-repo real-text corpus (Python stdlib sources), cached to disk, plus
perplexity evaluation and the quantization drivers."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.model_config import ArchConfig, BlockKind, FFNKind, QuantConfig
from repro.core.quantize_model import quantize_model_sequential
from repro.data.corpus import load_corpus_text
from repro.data.loader import TokenStream
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.quant.baselines import quantize_model_baseline
from repro.train.train_step import StepConfig, init_train_state, make_train_step
from repro.optim.adamw import AdamWConfig

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "tiny_lm")
SEQ = 256


def bench_arch(d_model=256, n_layers=4) -> ArchConfig:
    return ArchConfig(
        name="bench-byte-lm",
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2,
        d_ff=2 * d_model,
        vocab_size=260,
        head_dim=d_model // 4,
        block_kind=BlockKind.ATTENTION,
        ffn_kind=FFNKind.SWIGLU,
        max_seq_len=SEQ * 2,
    )


def corpus_tokens(max_bytes=4 << 20) -> np.ndarray:
    text = load_corpus_text(max_bytes=max_bytes)
    return ByteTokenizer().encode(text)


def get_trained_lm(steps: int = 400, seed: int = 0, force: bool = False):
    """Train (or load cached) the benchmark LM. Returns (model, params,
    train_tokens, heldout_tokens)."""
    cfg = bench_arch()
    model = build_model(cfg)
    toks = corpus_tokens()
    split = int(len(toks) * 0.9)
    train_toks, held = toks[:split], toks[split:]

    os.makedirs(CACHE_DIR, exist_ok=True)
    cache = os.path.join(CACHE_DIR, f"params_s{steps}_{seed}.npz")
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(seed))
    if os.path.exists(cache) and not force:
        data = np.load(cache)
        leaves = []
        import ml_dtypes  # noqa: F401
        flat, treedef = jax.tree.flatten(params_struct)
        for i, ref in enumerate(flat):
            a = data[f"leaf_{i}"]
            if a.dtype != ref.dtype:
                a = a.view(ref.dtype)
            leaves.append(jnp.asarray(a))
        return model, jax.tree.unflatten(treedef, leaves), train_toks, held

    params = model.init(jax.random.PRNGKey(seed))
    scfg = StepConfig(optimizer=AdamWConfig(lr=1e-3, weight_decay=0.01),
                      warmup_steps=40, total_steps=steps, remat=False)
    step = jax.jit(make_train_step(model, scfg), donate_argnums=(0,))
    state = init_train_state(params, scfg)
    stream = TokenStream(train_toks, batch=16, seq=SEQ, seed=seed)
    t0 = time.time()
    for i in range(steps):
        state, m = step(state, stream.batch_at(i))
        if (i + 1) % 100 == 0:
            print(f"  [train] step {i+1} loss {float(m['loss']):.3f} "
                  f"({time.time()-t0:.0f}s)")
    params = state.params
    flat, _ = jax.tree.flatten(params)
    np.savez(cache, **{f"leaf_{i}": (np.asarray(a).view(np.uint8)
                                     if np.asarray(a).dtype.kind not in
                                     "biufc" else np.asarray(a))
                       for i, a in enumerate(flat)})
    return model, params, train_toks, held


def perplexity(model, params, tokens: np.ndarray, n_windows: int = 24,
               seq: int = SEQ) -> float:
    """exp(mean next-token CE) over held-out windows."""
    n = min(n_windows, (len(tokens) - 1) // seq)
    total, count = 0.0, 0
    lf = jax.jit(lambda p, t, g: model.loss(p, t, g))
    for i in range(0, n, 4):
        bs = min(4, n - i)
        tok = np.stack([tokens[(i + j) * seq:(i + j + 1) * seq]
                        for j in range(bs)])
        tgt = np.stack([tokens[(i + j) * seq + 1:(i + j + 1) * seq + 1]
                        for j in range(bs)])
        ce = float(lf(params, jnp.asarray(tok), jnp.asarray(tgt)))
        total += ce * bs * seq
        count += bs * seq
    return float(np.exp(total / count))


def calib_batch(train_toks: np.ndarray, n_samples: int = 16,
                seq: int = SEQ, seed: int = 7) -> jnp.ndarray:
    stream = TokenStream(train_toks, batch=n_samples, seq=seq, seed=seed)
    return jnp.asarray(stream.batch_at(0)["tokens"])


def default_qcfg(**kw) -> QuantConfig:
    base = dict(group_size=32, n_outlier_groups=1, em_iters=12,
                calib_tokens=4096)
    base.update(kw)
    return QuantConfig(**base)


def quantize_ours(model, params, calib, qcfg=None):
    return quantize_model_sequential(model, params, calib,
                                     qcfg or default_qcfg())


def quantize_baseline(model, params, calib, method: str, qcfg=None):
    return quantize_model_baseline(model, params, calib,
                                   qcfg or default_qcfg(), method)
