"""Table 9 analogue: perplexity vs number of INT8 outlier groups
(0 collapses; more outliers help with diminishing returns)."""
from __future__ import annotations

import time

from benchmarks.common import (
    calib_batch,
    default_qcfg,
    get_trained_lm,
    perplexity,
    quantize_ours,
)


def run(quick: bool = False):
    model, params, train_toks, held = get_trained_lm()
    calib = calib_batch(train_toks)
    rows = []
    counts = [0, 1, 2, 3] if not quick else [1]
    for n in counts:
        t0 = time.time()
        qp = quantize_ours(model, params, calib,
                           default_qcfg(n_outlier_groups=n))
        ppl = perplexity(model, qp, held)
        dt = time.time() - t0
        rows.append({"name": f"table9/outlier-groups-{n}",
                     "us_per_call": dt * 1e6, "derived": f"ppl={ppl:.3f}"})
        print(f"  outlier groups {n}: ppl {ppl:10.3f}  ({dt:.0f}s)")
    return rows


if __name__ == "__main__":
    run()
