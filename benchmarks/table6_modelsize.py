"""Table 6 analogue: packed model size for the LLaMA family + all 10
assigned archs (exact byte accounting of the W(1+1)A(1x4) artifact:
2 bits/element + fp16 centers per (row, group) + INT8 outlier block +
fp16 residual layers), plus the measured size of the quantized tiny LM.
Paper claims >5x compression at group 128; we reproduce the accounting.
"""
from __future__ import annotations

from repro.config.model_config import QuantConfig
from repro.config.registry import ASSIGNED_ARCHS, get_arch

LLAMA_FAMILY = {
    "llama-7b": 6.74e9, "llama-13b": 13.0e9,
    "llama-30b": 32.5e9, "llama-65b": 65.2e9,
}


def packed_bytes_for(n_quantizable: float, n_residual: float,
                     qcfg: QuantConfig) -> float:
    bits = qcfg.storage_bits_per_weight()      # 2 + centers overhead
    # outlier fraction stored at 8 bit instead
    frac_out = qcfg.n_outlier_groups * qcfg.group_size / 4096.0
    per_w = (1 - frac_out) * bits + frac_out * 8
    return n_quantizable * per_w / 8 + n_residual * 2


def run(quick: bool = False):
    qcfg = QuantConfig()  # paper setting: group 128, 1 outlier group
    rows = []
    print("  LLaMA family (analytic, ~93% of params in FC layers):")
    for name, n in LLAMA_FAMILY.items():
        nq = 0.93 * n
        fp16 = n * 2
        ours = packed_bytes_for(nq, n - nq, qcfg)
        ratio = fp16 / ours
        rows.append({"name": f"table6/{name}", "us_per_call": 0,
                     "derived": f"fp16={fp16/2**30:.2f}GiB,"
                                f"ours={ours/2**30:.2f}GiB,x{ratio:.2f}"})
        print(f"    {name:10s} fp16 {fp16/2**30:7.2f}GiB -> "
              f"ours {ours/2**30:6.2f}GiB  ({ratio:.2f}x)")
    print("  assigned archs (analytic):")
    for arch in ASSIGNED_ARCHS:
        cfg = get_arch(arch)
        n = cfg.param_count()
        emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        nq = max(n - emb, 0) * 0.97
        fp16 = n * 2
        ours = packed_bytes_for(nq, n - nq, qcfg)
        rows.append({"name": f"table6/{arch}", "us_per_call": 0,
                     "derived": f"x{fp16/ours:.2f}"})
        print(f"    {arch:24s} {fp16/2**30:8.2f}GiB -> {ours/2**30:8.2f}GiB"
              f"  ({fp16/ours:.2f}x)")

    # measured on the real quantized tiny LM
    if not quick:
        from benchmarks.common import calib_batch, get_trained_lm, quantize_ours
        from repro.core.quantize_model import model_quantized_bytes
        model, params, train_toks, _ = get_trained_lm()
        qp = quantize_ours(model, params, calib_batch(train_toks))
        qb, fb = model_quantized_bytes(qp)
        _, fb_all = model_quantized_bytes(params)
        quantized_leaf_fp16 = fb_all - fb
        ratio = quantized_leaf_fp16 / max(qb, 1)
        rows.append({"name": "table6/tiny-lm-measured", "us_per_call": 0,
                     "derived": f"x{ratio:.2f}@group32"})
        print(f"    tiny-lm measured: FC leaves {quantized_leaf_fp16/2**20:.2f}MiB"
              f" -> {qb/2**20:.2f}MiB ({ratio:.2f}x at group 32)")
    return rows


if __name__ == "__main__":
    run()
