"""Table 5 analogue: the cumulative component ladder.

W1A4-GPTQ -> +outliers -> +minimum-distance (EM) -> +fine-grained group
-> +Hessian metric -> +A(1x4) balancing must be monotone-improving
(the paper's central ablation)."""
from __future__ import annotations

import time

from benchmarks.common import (
    calib_batch,
    default_qcfg,
    get_trained_lm,
    perplexity,
    quantize_ours,
)

LADDER = [
    # (label, QuantConfig overrides)
    ("w1a4-gptq",            dict(use_fine_grained=False, use_em=False,
                                  use_hessian_metric=False,
                                  use_act_balance=False,
                                  n_outlier_groups=0)),
    ("+outliers-int8",       dict(use_fine_grained=False, use_em=False,
                                  use_hessian_metric=False,
                                  use_act_balance=False)),
    ("+min-dist-em",         dict(use_fine_grained=False,
                                  use_hessian_metric=False,
                                  use_act_balance=False)),
    ("+fine-grained-w(1+1)", dict(use_hessian_metric=False,
                                  use_act_balance=False)),
    ("+hessian-metric",      dict(use_act_balance=False)),
    ("+a(1x4)-balancing",    dict()),
]


def run(quick: bool = False):
    model, params, train_toks, held = get_trained_lm()
    calib = calib_batch(train_toks)
    rows = []
    steps = LADDER if not quick else LADDER[::len(LADDER) - 1]
    for label, overrides in steps:
        t0 = time.time()
        qp = quantize_ours(model, params, calib, default_qcfg(**overrides))
        ppl = perplexity(model, qp, held)
        dt = time.time() - t0
        rows.append({"name": f"table5/{label}", "us_per_call": dt * 1e6,
                     "derived": f"ppl={ppl:.3f}"})
        print(f"  {label:24s} ppl {ppl:10.3f}  ({dt:.0f}s)")
    return rows


if __name__ == "__main__":
    run()
