"""Table 3 analogue (MMLU): downstream-task accuracy under quantization.

Our offline stand-in for multitask understanding is next-token TOP-1
accuracy on held-out code, split by token class (identifier letters /
punctuation-structure / whitespace-indentation) — "subdomains" whose
relative degradation mirrors the paper's category breakdown.  Claim
reproduced: ours stays close to FP16 accuracy while W2A4 baselines drop
sharply."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    SEQ,
    calib_batch,
    get_trained_lm,
    quantize_baseline,
    quantize_ours,
)

CLASSES = {
    "letters": lambda b: ((b >= 65) & (b <= 90)) | ((b >= 97) & (b <= 122)),
    "punct": lambda b: np.isin(b, np.frombuffer(b"()[]{}:,.=+-*<>", np.uint8)
                       .astype(np.int32)),
    "space": lambda b: np.isin(b, np.frombuffer(b" \n\t", np.uint8)
                       .astype(np.int32)),
}


def accuracy_by_class(model, params, tokens, n_windows=16):
    f = jax.jit(lambda p, t: model.apply(p, t)[0])
    correct = {k: 0 for k in CLASSES}
    total = {k: 0 for k in CLASSES}
    for i in range(0, n_windows, 4):
        bs = min(4, n_windows - i)
        tok = np.stack([tokens[(i + j) * SEQ:(i + j + 1) * SEQ]
                        for j in range(bs)])
        tgt = np.stack([tokens[(i + j) * SEQ + 1:(i + j + 1) * SEQ + 1]
                        for j in range(bs)])
        pred = np.asarray(jnp.argmax(f(params, jnp.asarray(tok)), -1))
        hit = (pred == tgt)
        for k, sel in CLASSES.items():
            m = sel(tgt)
            correct[k] += int(hit[m].sum())
            total[k] += int(m.sum())
    return {k: correct[k] / max(total[k], 1) for k in CLASSES}


def run(quick: bool = False):
    model, params, train_toks, held = get_trained_lm()
    calib = calib_batch(train_toks)
    methods = [("fp16", None), ("ours-w(1+1)a(1x4)", "ours")]
    if not quick:
        methods += [("atom-w2a4", "atom-w2a4"), ("rtn-w2a4", "rtn-w2a4")]
    rows = []
    print(f"  {'method':20s} {'letters':>8s} {'punct':>8s} {'space':>8s} "
          f"{'avg':>8s}")
    for name, method in methods:
        t0 = time.time()
        if method is None:
            qp = params
        elif method == "ours":
            qp = quantize_ours(model, params, calib)
        else:
            qp = quantize_baseline(model, params, calib, method)
        acc = accuracy_by_class(model, qp, held)
        avg = sum(acc.values()) / len(acc)
        rows.append({"name": f"table3/{name}",
                     "us_per_call": (time.time() - t0) * 1e6,
                     "derived": f"avg_top1={avg:.3f}"})
        print(f"  {name:20s} {acc['letters']:8.3f} {acc['punct']:8.3f} "
              f"{acc['space']:8.3f} {avg:8.3f}")
    return rows


if __name__ == "__main__":
    run()
